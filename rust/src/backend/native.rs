//! Pure-Rust [`Backend`]: img2col GEMM forward + the compacted sparse
//! backward from [`super::sparse`], implemented over the plan/workspace
//! path — one im2col per layer per fused fwd+bwd, every scratch buffer
//! borrowed from the [`Conv2dPlan`]. All GEMMs run through the
//! cache-blocked microkernel in [`super::gemm`] (pack buffers live in the
//! plan's workspace, so per-worker plans stay lock-free). Zero FFI, runs
//! anywhere — this is the crate's default executor and the correctness
//! anchor the fixture tests pin against `python/compile/kernels/ref.py`.

use super::gemm::{self, gemm_into_tiled, nr_for, Kernel, Operand};
use super::im2col::col_w_into;
use super::plan::Conv2dPlan;
use super::sparse::sparse_bwd_with_cols;
use super::{Backend, Conv2d, ConvGrads};

/// The pure-Rust conv executor (see module docs). Stateless and `Copy`:
/// all mutable scratch lives in the caller's [`Conv2dPlan`], so one value
/// can be shared freely across the parallel executor's worker threads.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl NativeBackend {
    /// A native backend (stateless; equivalent to `NativeBackend::default()`).
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn conv2d_fwd_planned(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        b: Option<&[f32]>,
    ) -> Vec<f32> {
        let cfg = *plan.cfg();
        let (m, n) = (cfg.m(), cfg.n());
        let (ho, wo) = (cfg.hout(), cfg.wout());
        plan.build_cols(x); // cached for the backward's dW GEMM
        col_w_into(&cfg, w, &mut plan.cw);
        // ycol = cols · col_W  (M, Cout), blocked kernel, pack reused;
        // the forward is dense, so the tile width follows Cout
        gemm_into_tiled(
            m,
            n,
            cfg.cout,
            Operand::Dense(&plan.cols),
            Operand::Dense(&plan.cw),
            &mut plan.ycol,
            &mut plan.ws.pack,
            Kernel::active(),
            nr_for(cfg.cout),
        );

        // (M, Cout) -> NCHW, folding the bias in during the transpose
        let mut y = vec![0f32; cfg.out_len()];
        for bi in 0..cfg.bt {
            for o in 0..cfg.cout {
                let bias = b.map_or(0.0, |bb| bb[o]);
                let plane = &mut y[(bi * cfg.cout + o) * ho * wo..][..ho * wo];
                for (pix, v) in plane.iter_mut().enumerate() {
                    *v = plan.ycol[(bi * ho * wo + pix) * cfg.cout + o] + bias;
                }
            }
        }
        y
    }

    fn conv2d_bwd_planned_with(
        &self,
        plan: &mut Conv2dPlan,
        x: &[f32],
        w: &[f32],
        g: &[f32],
        keep_idx: &[usize],
        need_dx: bool,
    ) -> ConvGrads {
        let cfg = *plan.cfg();
        if plan.cols_valid {
            // Always-on, release builds included: a backward running
            // against a *different* input's cached columns silently
            // corrupts dW, so the cheap length + endpoint-bits
            // fingerprint fails loudly instead of letting it through.
            assert!(plan.cols_match(x), "plan cols were cached from a different input");
        } else {
            plan.build_cols(x);
        }
        plan.cols_valid = false; // the cache is keyed to one fwd/bwd pair
        let (cols, ws) = plan.split_cols_ws();
        sparse_bwd_with_cols(&cfg, cols, w, g, keep_idx, need_dx, ws)
    }

    fn gemm(&self, m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        gemm::gemm(m, k, n, a, b)
    }

    fn bias_add(&self, cfg: &Conv2d, y: &mut [f32], b: &[f32]) {
        let hw = cfg.hout() * cfg.wout();
        assert_eq!(y.len(), cfg.out_len(), "bias_add activation length");
        assert_eq!(b.len(), cfg.cout, "bias_add bias length");
        for bi in 0..cfg.bt {
            for (o, &bias) in b.iter().enumerate() {
                let plane = &mut y[(bi * cfg.cout + o) * hw..][..hw];
                for v in plane.iter_mut() {
                    *v += bias;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_identity_and_known_product() {
        let be = NativeBackend::new();
        // 2x2 identity
        let c = be.gemm(2, 2, 2, &[1.0, 0.0, 0.0, 1.0], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c, vec![1.0, 2.0, 3.0, 4.0]);
        // (1x3) . (3x2)
        let c = be.gemm(1, 3, 2, &[1.0, 2.0, 3.0], &[1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(c, vec![14.0, 32.0]);
    }

    #[test]
    fn nan_in_b_propagates_through_zero_a_entries() {
        // regression: the old kernel skipped a == 0.0 terms, silently
        // swallowing NaN/Inf coming from the B operand
        let be = NativeBackend::new();
        let c = be.gemm(1, 2, 2, &[0.0, 1.0], &[f32::NAN, 1.0, 2.0, 3.0]);
        assert!(c[0].is_nan(), "0·NaN must stay NaN, not be skipped");
        assert_eq!(c[1], 3.0); // 0·1 + 1·3
        let c = be.gemm(1, 1, 1, &[0.0], &[f32::INFINITY]);
        assert!(c[0].is_nan(), "0·Inf must stay NaN, not be skipped");
    }

    #[test]
    fn conv_fwd_1x1_kernel_is_channel_mix() {
        // 1x1 conv == per-pixel matmul over channels: easy to hand-check.
        let cfg = Conv2d { bt: 1, cin: 2, h: 2, w: 2, cout: 1, k: 1, stride: 1, padding: 0 };
        let x = vec![1.0, 2.0, 3.0, 4.0, 10.0, 20.0, 30.0, 40.0]; // (1,2,2,2)
        let w = vec![2.0, 0.5]; // (1,2,1,1)
        let y = NativeBackend::new().conv2d_fwd(&cfg, &x, &w, Some(&[1.0]));
        assert_eq!(y, vec![2.0 + 5.0 + 1.0, 4.0 + 10.0 + 1.0, 6.0 + 15.0 + 1.0, 8.0 + 20.0 + 1.0]);
    }

    #[test]
    fn dense_bwd_keeps_every_channel() {
        let cfg = Conv2d { bt: 1, cin: 1, h: 4, w: 4, cout: 3, k: 3, stride: 1, padding: 1 };
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| i as f32 * 0.1).collect();
        let w: Vec<f32> = (0..cfg.w_len()).map(|i| (i % 3) as f32 - 1.0).collect();
        let g: Vec<f32> = (0..cfg.out_len()).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
        let out = NativeBackend::new().conv2d_bwd_ssprop(&cfg, &x, &w, &g, 0.0, true);
        assert_eq!(out.keep_idx, vec![0, 1, 2]);
        assert_eq!(out.dx.len(), cfg.in_len());
        // skipping dx leaves dw/db identical and dx empty
        let nodx = NativeBackend::new().conv2d_bwd_ssprop(&cfg, &x, &w, &g, 0.0, false);
        assert!(nodx.dx.is_empty());
        assert_eq!(nodx.dw, out.dw);
        assert_eq!(nodx.db, out.db);
        assert_eq!(out.dw.len(), cfg.w_len());
        // dense db = plain sum of g per channel
        let hw = cfg.hout() * cfg.wout();
        for o in 0..3 {
            let want: f32 = g[o * hw..(o + 1) * hw].iter().sum();
            assert!((out.db[o] - want).abs() < 1e-6);
        }
    }

    #[test]
    fn fused_plan_path_matches_op_path() {
        let be = NativeBackend::new();
        let cfg = Conv2d { bt: 2, cin: 2, h: 5, w: 4, cout: 4, k: 3, stride: 2, padding: 1 };
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| ((i * 7) % 13) as f32 * 0.1 - 0.6).collect();
        let w: Vec<f32> = (0..cfg.w_len()).map(|i| ((i * 5) % 11) as f32 * 0.05 - 0.25).collect();
        let b: Vec<f32> = (0..cfg.cout).map(|i| i as f32 * 0.1).collect();
        let g: Vec<f32> = (0..cfg.out_len()).map(|i| ((i * 3) % 7) as f32 - 3.0).collect();
        let mut plan = Conv2dPlan::new(cfg);
        for d in [0.0, 0.5] {
            let (y, grads) = be.conv2d_fwd_bwd(&mut plan, &x, &w, Some(&b), &g, d, true);
            assert_eq!(y, be.conv2d_fwd(&cfg, &x, &w, Some(&b)), "fwd at d={d}");
            let want = be.conv2d_bwd_ssprop(&cfg, &x, &w, &g, d, true);
            assert_eq!(grads.keep_idx, want.keep_idx, "keep at d={d}");
            assert_eq!(grads.dx, want.dx, "dx at d={d}");
            assert_eq!(grads.dw, want.dw, "dw at d={d}");
            assert_eq!(grads.db, want.db, "db at d={d}");
        }
        assert_eq!(plan.cols_builds(), 2, "exactly one im2col per fused pair");
    }

    #[test]
    fn bwd_planned_with_matches_drop_rate_route() {
        use crate::backend::sparse::select_channels;
        let be = NativeBackend::new();
        let cfg = Conv2d { bt: 1, cin: 2, h: 4, w: 4, cout: 4, k: 3, stride: 1, padding: 1 };
        let x: Vec<f32> = (0..cfg.in_len()).map(|i| ((i * 3) % 11) as f32 * 0.2 - 1.0).collect();
        let w: Vec<f32> = (0..cfg.w_len()).map(|i| ((i * 7) % 5) as f32 * 0.1 - 0.2).collect();
        let g: Vec<f32> = (0..cfg.out_len()).map(|i| ((i * 5) % 13) as f32 - 6.0).collect();
        for d in [0.0, 0.5] {
            let keep = select_channels(&cfg, &g, d);
            let via_rate = be.conv2d_bwd_planned(&mut Conv2dPlan::new(cfg), &x, &w, &g, d, true);
            let via_keep =
                be.conv2d_bwd_planned_with(&mut Conv2dPlan::new(cfg), &x, &w, &g, &keep, true);
            assert_eq!(via_rate.keep_idx, via_keep.keep_idx, "d={d}");
            assert_eq!(via_rate.dx, via_keep.dx, "d={d}");
            assert_eq!(via_rate.dw, via_keep.dw, "d={d}");
            assert_eq!(via_rate.db, via_keep.db, "d={d}");
        }
    }

    #[test]
    fn bias_add_broadcasts_per_channel() {
        let cfg = Conv2d { bt: 2, cin: 1, h: 2, w: 2, cout: 2, k: 1, stride: 1, padding: 0 };
        let mut y = vec![0f32; cfg.out_len()];
        NativeBackend::new().bias_add(&cfg, &mut y, &[1.0, -2.0]);
        let mut want = vec![1.0f32; 4];
        want.extend([-2.0; 4]);
        let want = [want.clone(), want].concat();
        assert_eq!(y, want);
    }
}
