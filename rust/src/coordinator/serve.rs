//! Inference serving: batched classify requests over a (BN-folded) model.
//!
//! This is the coordinator's answer path, built from three pieces the rest
//! of the crate already provides (`docs/ARCHITECTURE.md` § Inference
//! path):
//!
//! * **Folded model.** [`Server::from_checkpoint`] accepts either a folded
//!   checkpoint (artifact tagged [`crate::backend::fold::FOLDED_TAG`]) or a
//!   raw training checkpoint, which it folds in memory on load
//!   ([`crate::backend::fold`]). Specs with no BatchNorm simply serve
//!   unfolded — a no-op, not an error. When the caller names the model it
//!   expects (`--model`), a mismatch against the checkpoint's recorded
//!   spec is the typed [`ServeError::SpecMismatch`] naming both.
//! * **No-workspace walk.** Answers run through
//!   [`WorkerPool::eval_logits`] — forward-only, per-worker conv plans
//!   persisting across requests *and* across drains (the pool's workers
//!   live as long as the server), no gradient accumulators or backward
//!   scratch ever allocated, Dropout and BN-training branches skipped
//!   (eval mode).
//! * **Batching queue.** [`Server::serve`] drains a FIFO of
//!   [`ClassifyRequest`]s, coalescing up to [`ServeConfig::batch`] requests
//!   per inference call (the tail batch may be smaller) and sharding each
//!   coalesced batch across the pool's threads. Answers come back in
//!   request order and are **bit-identical** to serving the same requests
//!   one at a time at any thread count: eval-mode layers are per-example,
//!   so neither coalescing nor sharding changes a single bit
//!   (`rust/tests/determinism.rs` pins this at t ∈ {1, 2, 4}).
//!
//! [`ServeStats`] reports the latency distribution (p50/p99 over
//! per-request queue→answer times) and throughput, which the `ssprop
//! serve --json` path records as `BENCH_serve.json` through
//! [`crate::bench_report`].

use std::collections::VecDeque;
use std::fmt;
use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::backend::fold::{self, FoldError};
use crate::backend::zoo::parse_model_spec;
use crate::backend::{default_backend, Backend, ExecConfig, Graph, WorkerPool};
use crate::coordinator::checkpoint;

/// Typed serving failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The caller asked to serve one model but the checkpoint records
    /// another; serving it anyway would answer with the wrong network.
    SpecMismatch {
        /// Canonical spec recorded in the checkpoint artifact.
        saved: String,
        /// Canonical spec the caller requested.
        requested: String,
    },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::SpecMismatch { saved, requested } => write!(
                f,
                "checkpoint holds model {saved:?} but {requested:?} was requested; \
                 pass the matching --model or drop the flag to use the recorded spec"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Serving knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    /// Most requests coalesced into one inference call (≥ 1; the queue
    /// tail may produce a smaller final batch).
    pub batch: usize,
    /// Worker threads each coalesced batch is sharded over (0 =
    /// auto-detect via [`ExecConfig::auto`]'s documented clamp).
    pub threads: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch: 32, threads: 1 }
    }
}

/// One queued classification request: an input image in the model's
/// flattened NCHW geometry plus a caller-chosen id echoed in the answer.
#[derive(Debug, Clone)]
pub struct ClassifyRequest {
    /// Caller's correlation id (answers keep request order regardless).
    pub id: u64,
    /// Flattened input, length = the model's input volume.
    pub pixels: Vec<f32>,
}

/// One answered request.
#[derive(Debug, Clone)]
pub struct Answer {
    /// The request's correlation id.
    pub id: u64,
    /// Argmax class (first index on exact ties — deterministic).
    pub class: usize,
    /// The full logit row, for callers that want scores or top-k.
    pub logits: Vec<f32>,
}

/// Latency/throughput record of one [`Server::serve`] drain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServeStats {
    /// Requests answered.
    pub answered: usize,
    /// Inference calls issued (coalesced batches, tail included).
    pub batches: usize,
    /// Median per-request latency (nanoseconds, queue→answer; every
    /// request in a coalesced batch shares its batch's wall time).
    pub p50_ns: u64,
    /// 99th-percentile per-request latency (nanoseconds, nearest-rank).
    pub p99_ns: u64,
    /// Wall time of the whole drain (nanoseconds).
    pub total_ns: u64,
    /// Answers per second over the whole drain.
    pub throughput_rps: f64,
}

/// A loaded model plus the persistent worker pool needed to answer
/// classify requests. Construct once per checkpoint and reuse — the
/// pool's workers and their per-worker forward plans persist across
/// [`Server::serve`] calls.
pub struct Server {
    model: Graph,
    backend: Box<dyn Backend>,
    pool: WorkerPool,
    cfg: ServeConfig,
    n_in: usize,
    classes: usize,
    folded: usize,
    artifact: String,
    epoch: usize,
}

impl fmt::Debug for Server {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Server")
            .field("spec", &self.model.spec())
            .field("artifact", &self.artifact)
            .field("folded", &self.folded)
            .field("cfg", &self.cfg)
            .finish()
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate().skip(1) {
        if v > row[best] {
            best = i;
        }
    }
    best
}

impl Server {
    /// Load a checkpoint into a serving model. Folded checkpoints
    /// ([`crate::backend::fold::FOLDED_TAG`]) restore directly into the
    /// BN-free graph; raw training checkpoints are folded in memory (a
    /// spec with no BatchNorm serves unfolded — a skip, not an error).
    /// `requested`, when given, must canonicalize to the checkpoint's
    /// recorded spec or the typed [`ServeError::SpecMismatch`] is
    /// returned naming both.
    pub fn from_checkpoint(
        path: &Path,
        requested: Option<&str>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let (state, artifact, epoch) = checkpoint::load_tensors(path)?;
        let base = fold::base_artifact(&artifact);
        let saved_spec = checkpoint::artifact_model_spec(base)
            .ok_or_else(|| FoldError::BadArtifact { artifact: artifact.clone() })?
            .to_string();
        if let Some(req) = requested {
            let req_canon = parse_model_spec(req)?.canonical();
            if req_canon != saved_spec {
                let err = ServeError::SpecMismatch { saved: saved_spec, requested: req_canon };
                return Err(err.into());
            }
        }
        let mut model = fold::model_for_artifact(&artifact)?;
        let tensors: Vec<_> = state.into_iter().collect();
        let folded = if fold::is_folded(&artifact) {
            // Replay the structural fold, then restore the folded values
            // over it (the checkpoint holds exactly the folded keys).
            let n = fold::fold_graph(&mut model);
            model.load_state_tensors(&tensors)?;
            n
        } else {
            model.load_state_tensors(&tensors)?;
            fold::fold_graph(&mut model)
        };
        let n_in = model.in_shape().volume();
        let classes = model.out_features();
        // threads = 0 is meaningful (auto-detect); only batch clamps.
        let pool = WorkerPool::new(ExecConfig::with_threads(cfg.threads));
        let cfg = ServeConfig { batch: cfg.batch.max(1), threads: pool.threads() };
        Ok(Server {
            model,
            backend: default_backend(),
            pool,
            cfg,
            n_in,
            classes,
            folded,
            artifact,
            epoch,
        })
    }

    /// Canonical spec of the serving model.
    pub fn spec(&self) -> &str {
        self.model.spec()
    }

    /// Artifact name recorded in the loaded checkpoint.
    pub fn artifact(&self) -> &str {
        &self.artifact
    }

    /// Epoch recorded in the loaded checkpoint.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// BatchNorm nodes folded away at load (0 = serving unfolded).
    pub fn folded(&self) -> usize {
        self.folded
    }

    /// Flattened input length one request must carry.
    pub fn input_len(&self) -> usize {
        self.n_in
    }

    /// Classifier output count.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Current serving knobs (`threads` is always the resolved count,
    /// even when the server was configured with `0` = auto).
    pub fn config(&self) -> ServeConfig {
        self.cfg
    }

    /// Resolved worker count of the serving pool.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Re-shard future batches over `threads` workers (`0` = auto-detect).
    /// Replaces the worker pool — the old workers join, fresh ones spawn —
    /// so workspaces restart cold; answers stay bit-identical at any
    /// thread count regardless.
    pub fn set_threads(&mut self, threads: usize) {
        self.pool = WorkerPool::new(ExecConfig::with_threads(threads));
        self.cfg.threads = self.pool.threads();
    }

    /// Change the coalescing limit (clamped to ≥ 1); answers stay
    /// bit-identical — batching never changes a logit.
    pub fn set_batch(&mut self, batch: usize) {
        self.cfg.batch = batch.max(1);
    }

    /// Raw logits of a prepared batch (`bt` rows), through the same
    /// forward-only sharded walk [`Server::serve`] uses.
    pub fn logits(&mut self, x: &[f32], bt: usize) -> Vec<f32> {
        self.pool.eval_logits(&self.model, self.backend.as_ref(), x, bt)
    }

    /// Mean (loss, accuracy) of a labelled batch on the serving model —
    /// the eval cross-check the determinism suite compares answers
    /// against.
    pub fn eval_batch(&mut self, x: &[f32], y: &[i32]) -> (f64, f64) {
        self.pool.eval_batch(&self.model, self.backend.as_ref(), x, y)
    }

    /// Drain a request queue: coalesce up to [`ServeConfig::batch`]
    /// requests per inference call (FIFO; the final batch may be smaller),
    /// shard each call across the thread pool, and answer in request
    /// order. Panics if a request's pixel length does not match the
    /// model's input volume. Returns the answers plus the latency/
    /// throughput record of the drain.
    pub fn serve(&mut self, requests: Vec<ClassifyRequest>) -> (Vec<Answer>, ServeStats) {
        let t_all = Instant::now();
        let mut queue: VecDeque<ClassifyRequest> = requests.into();
        let mut answers = Vec::with_capacity(queue.len());
        let mut latencies: Vec<u64> = Vec::with_capacity(queue.len());
        let mut batches = 0usize;
        while !queue.is_empty() {
            let take = queue.len().min(self.cfg.batch);
            let t0 = Instant::now();
            let mut ids = Vec::with_capacity(take);
            let mut x = Vec::with_capacity(take * self.n_in);
            for _ in 0..take {
                let r = queue.pop_front().expect("queue checked non-empty");
                assert_eq!(r.pixels.len(), self.n_in, "classify request geometry");
                ids.push(r.id);
                x.extend_from_slice(&r.pixels);
            }
            let logits = self.pool.eval_logits(&self.model, self.backend.as_ref(), &x, take);
            let batch_ns = t0.elapsed().as_nanos() as u64;
            for (row, id) in ids.into_iter().enumerate() {
                let lg = logits[row * self.classes..(row + 1) * self.classes].to_vec();
                answers.push(Answer { id, class: argmax(&lg), logits: lg });
                latencies.push(batch_ns);
            }
            batches += 1;
        }
        let total_ns = t_all.elapsed().as_nanos() as u64;
        latencies.sort_unstable();
        let pct = |p: usize| -> u64 {
            if latencies.is_empty() {
                0
            } else {
                latencies[(latencies.len() - 1) * p / 100]
            }
        };
        let throughput_rps = if total_ns == 0 {
            0.0
        } else {
            answers.len() as f64 * 1e9 / total_ns as f64
        };
        let stats = ServeStats {
            answered: answers.len(),
            batches,
            p50_ns: pct(50),
            p99_ns: pct(99),
            total_ns,
            throughput_rps,
        };
        (answers, stats)
    }
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    use super::*;
    use crate::backend::build_model;
    use crate::tensorstore::Tensor;
    use crate::util::rng::Pcg;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("ssprop_serve_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn save_preset(path: &std::path::Path, dataset: &str, spec: &str, seed: u64) {
        let ds = crate::data::spec(dataset).unwrap();
        let parsed = parse_model_spec(spec).unwrap();
        let model = build_model(&parsed, ds.channels, ds.img, ds.classes, seed).unwrap();
        let state: HashMap<String, Tensor> = model.state_tensors().into_iter().collect();
        let artifact = format!("native_{dataset}:{}", parsed.canonical());
        checkpoint::save_tensors(path, &state, &artifact, 1).unwrap();
    }

    fn requests(n: usize, n_in: usize, seed: u64) -> Vec<ClassifyRequest> {
        let mut rng = Pcg::new(seed, 9);
        (0..n)
            .map(|i| ClassifyRequest {
                id: i as u64,
                pixels: (0..n_in).map(|_| rng.normal()).collect(),
            })
            .collect()
    }

    #[test]
    fn spec_mismatch_is_typed_and_names_both() {
        let dir = tmp_dir("mismatch");
        let ck = dir.join("vgg.tstore");
        save_preset(&ck, "mnist", "vgg-tiny-w4", 5);
        let err =
            Server::from_checkpoint(&ck, Some("vgg-tiny-w8"), ServeConfig::default()).unwrap_err();
        match err.downcast_ref::<ServeError>() {
            Some(ServeError::SpecMismatch { saved, requested }) => {
                assert_eq!(saved, "vgg-tiny-w4");
                assert_eq!(requested, "vgg-tiny-w8");
            }
            other => panic!("expected SpecMismatch, got {other:?}: {err}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("vgg-tiny-w4") && msg.contains("vgg-tiny-w8"), "{msg}");
    }

    #[test]
    fn bn_less_checkpoints_serve_unfolded() {
        let dir = tmp_dir("nobn");
        let ck = dir.join("vgg.tstore");
        save_preset(&ck, "mnist", "vgg-tiny-w4", 5);
        let srv = Server::from_checkpoint(&ck, Some("vgg-tiny-w4"), ServeConfig::default())
            .expect("no-BN spec must serve, not error");
        assert_eq!(srv.folded(), 0);
        assert_eq!(srv.spec(), "vgg-tiny-w4");
    }

    #[test]
    fn resnet_checkpoints_fold_on_load_and_answer_in_order() {
        let dir = tmp_dir("resnet");
        let ck = dir.join("rn.tstore");
        save_preset(&ck, "mnist", "resnet-tiny-w4-b1", 11);
        let cfg = ServeConfig { batch: 4, threads: 2 };
        let mut srv = Server::from_checkpoint(&ck, None, cfg).unwrap();
        assert!(srv.folded() > 0, "resnet-tiny carries BatchNorm to fold");
        assert_eq!(srv.epoch(), 1);

        let reqs = requests(7, srv.input_len(), 3);
        let pixels: Vec<Vec<f32>> = reqs.iter().map(|r| r.pixels.clone()).collect();
        let (answers, stats) = srv.serve(reqs);
        assert_eq!(stats.answered, 7);
        assert_eq!(stats.batches, 2, "7 requests at batch 4 coalesce as 4 + 3");
        assert!(stats.p50_ns <= stats.p99_ns);
        assert!(stats.throughput_rps > 0.0);

        for (i, ans) in answers.iter().enumerate() {
            assert_eq!(ans.id, i as u64, "answers keep request order");
            let solo = srv.logits(&pixels[i], 1);
            assert_eq!(solo.len(), ans.logits.len());
            for (a, b) in ans.logits.iter().zip(&solo) {
                assert_eq!(a.to_bits(), b.to_bits(), "batched answer {i} must be bitwise");
            }
            assert_eq!(ans.class, argmax(&solo));
        }
    }

    #[test]
    fn argmax_ties_break_to_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn zero_threads_serves_on_an_auto_sized_pool() {
        let dir = tmp_dir("auto");
        let ck = dir.join("vgg.tstore");
        save_preset(&ck, "mnist", "vgg-tiny-w4", 5);
        let cfg = ServeConfig { batch: 4, threads: 0 };
        let mut srv = Server::from_checkpoint(&ck, None, cfg).unwrap();
        let resolved = srv.threads();
        assert!(
            (1..=crate::backend::parallel::MAX_AUTO_THREADS).contains(&resolved),
            "auto resolved to {resolved}"
        );
        assert_eq!(srv.config().threads, resolved, "config reports the resolved count");
        let (answers, stats) = srv.serve(requests(5, srv.input_len(), 2));
        assert_eq!(stats.answered, 5);
        assert_eq!(answers.len(), 5);
        // set_threads(0) re-resolves rather than clamping to 1
        srv.set_threads(0);
        assert_eq!(srv.threads(), resolved);
    }

    #[test]
    fn repeated_drains_on_one_server_are_bitwise_identical() {
        let dir = tmp_dir("redrain");
        let ck = dir.join("rn.tstore");
        save_preset(&ck, "mnist", "resnet-tiny-w4-b1", 11);
        let cfg = ServeConfig { batch: 4, threads: 2 };
        let mut srv = Server::from_checkpoint(&ck, None, cfg).unwrap();
        let reqs = requests(7, srv.input_len(), 3);
        let (first, _) = srv.serve(reqs.clone());
        // Same queue again on the same (now warm) pool: the workers and
        // their plan workspaces are reused, and every bit must match.
        let (second, _) = srv.serve(reqs);
        assert_eq!(first.len(), second.len());
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            for (la, lb) in a.logits.iter().zip(&b.logits) {
                assert_eq!(la.to_bits(), lb.to_bits(), "re-drain must be bitwise");
            }
        }
    }
}
