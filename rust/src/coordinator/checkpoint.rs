//! Checkpointing: persist/restore a training job's state leaves (params,
//! optimizer moments, BN statistics) as a tensorstore file, plus a JSON
//! sidecar with the training position. Checkpoints are interchangeable with
//! the Python side (same format as `*.init.tstore`) and across executors:
//! the core works on host [`Tensor`]s; the `pjrt` feature adds
//! literal-keyed wrappers for the PJRT trainer's state maps.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::tensorstore::{self, Tensor};
use crate::util::json::{num, obj, s, Json};

/// Save named tensors (sorted by name for stable files) + sidecar metadata.
pub fn save_tensors<P: AsRef<Path>>(
    path: P,
    state: &HashMap<String, Tensor>,
    artifact: &str,
    epoch: usize,
) -> Result<()> {
    let mut names: Vec<&String> = state.keys().collect();
    names.sort();
    let mut tensors = Vec::with_capacity(names.len());
    for name in names {
        tensors.push((name.clone(), state[name].clone()));
    }
    tensorstore::write(path.as_ref(), &tensors)?;
    let meta = obj(vec![
        ("artifact", s(artifact)),
        ("epoch", num(epoch as f64)),
        ("leaves", num(tensors.len() as f64)),
    ]);
    std::fs::write(sidecar(path.as_ref()), meta.to_string())?;
    Ok(())
}

/// Load a checkpoint back into (state tensors, artifact name, epoch).
pub fn load_tensors<P: AsRef<Path>>(path: P) -> Result<(HashMap<String, Tensor>, String, usize)> {
    let state: HashMap<String, Tensor> = tensorstore::read(path.as_ref())?.into_iter().collect();
    let meta_text = std::fs::read_to_string(sidecar(path.as_ref()))
        .with_context(|| "checkpoint sidecar missing")?;
    let meta = Json::parse(&meta_text).map_err(anyhow::Error::msg)?;
    let artifact = meta.str_field("artifact").map_err(anyhow::Error::msg)?.to_string();
    let epoch = meta.usize_field("epoch").map_err(anyhow::Error::msg)?;
    Ok((state, artifact, epoch))
}

/// PJRT wrapper: save a literal-keyed state map.
#[cfg(feature = "pjrt")]
pub fn save<P: AsRef<Path>>(
    path: P,
    state: &HashMap<String, xla::Literal>,
    artifact: &str,
    epoch: usize,
) -> Result<()> {
    let mut tensors = HashMap::with_capacity(state.len());
    for (name, lit) in state {
        tensors.insert(name.clone(), crate::runtime::literal_to_tensor(lit)?);
    }
    save_tensors(path, &tensors, artifact, epoch)
}

/// PJRT wrapper: load a checkpoint into a literal-keyed state map.
#[cfg(feature = "pjrt")]
pub fn load<P: AsRef<Path>>(path: P) -> Result<(HashMap<String, xla::Literal>, String, usize)> {
    let (tensors, artifact, epoch) = load_tensors(path)?;
    let mut state = HashMap::with_capacity(tensors.len());
    for (name, t) in tensors {
        state.insert(name, crate::runtime::tensor_to_literal(&t)?);
    }
    Ok((state, artifact, epoch))
}

fn sidecar(path: &Path) -> std::path::PathBuf {
    path.with_extension("meta.json")
}

/// Model spec recorded in a native checkpoint's artifact field
/// (`native_{dataset}:{model_spec}`), or `None` for artifacts without one
/// (PJRT checkpoints, pre-model-zoo native checkpoints — those stay
/// loadable, shape checks still apply downstream).
pub fn artifact_model_spec(artifact: &str) -> Option<&str> {
    artifact.split_once(':').map(|(_, spec)| spec)
}

/// Dataset name recorded in a native checkpoint's artifact field
/// (`native_{dataset}:{model_spec}`), or `None` for artifacts without one.
/// The serving path resolves the input geometry and class count through
/// this (`data::spec`), so a checkpoint is self-describing.
pub fn artifact_dataset(artifact: &str) -> Option<&str> {
    artifact.strip_prefix("native_").and_then(|rest| rest.split_once(':')).map(|(ds, _)| ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_tensor_state() {
        let dir = std::env::temp_dir().join("ssprop_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ck.tstore");
        let mut state = HashMap::new();
        state.insert(
            "param['w']".to_string(),
            Tensor::from_f32(vec![2, 2], &[1.0, 2.0, 3.0, 4.0]),
        );
        state.insert("opt['m']".to_string(), Tensor::from_f32(vec![2], &[0.5, -0.5]));
        save_tensors(&p, &state, "resnet18_cifar10", 7).unwrap();
        let (back, artifact, epoch) = load_tensors(&p).unwrap();
        assert_eq!(artifact, "resnet18_cifar10");
        assert_eq!(epoch, 7);
        assert_eq!(back.len(), 2);
        assert_eq!(back["param['w']"].to_f32(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn artifact_model_spec_extraction() {
        assert_eq!(artifact_model_spec("native_mnist:vgg-tiny-w8"), Some("vgg-tiny-w8"));
        assert_eq!(artifact_model_spec("resnet18_cifar10"), None);
        assert_eq!(artifact_model_spec("native_mnist"), None);
    }

    #[test]
    fn artifact_dataset_extraction() {
        assert_eq!(artifact_dataset("native_cifar10:resnet-tiny-w8-b1"), Some("cifar10"));
        assert_eq!(artifact_dataset("resnet18_cifar10"), None);
        assert_eq!(artifact_dataset("native_mnist"), None);
    }

    #[test]
    fn missing_sidecar_is_an_error() {
        let dir = std::env::temp_dir().join("ssprop_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("nosidecar.tstore");
        tensorstore::write(&p, &[("w".to_string(), Tensor::from_f32(vec![1], &[1.0]))]).unwrap();
        let _ = std::fs::remove_file(sidecar(&p));
        assert!(load_tensors(&p).is_err());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn roundtrip_literal_state() {
        use crate::runtime::f32_literal;
        let dir = std::env::temp_dir().join("ssprop_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ck_lit.tstore");
        let mut state = HashMap::new();
        state
            .insert("param['w']".to_string(), f32_literal(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap());
        save(&p, &state, "resnet18_cifar10", 3).unwrap();
        let (back, artifact, epoch) = load(&p).unwrap();
        assert_eq!((artifact.as_str(), epoch), ("resnet18_cifar10", 3));
        assert_eq!(back["param['w']"].to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
