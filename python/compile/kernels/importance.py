"""Pallas channel-importance kernel — the ssProp selection reduction.

Computes mean(|g|) over (Bt, H, W) per output channel (Fig. 1a). This is the
*overhead* term of the paper's Eq. 9: (Bt*Hout*Wout - 1) additions per
channel, which must stay far below the saved matmul FLOPs (it does: Eq. 10
bounds the break-even drop rate at ~3%).

On TPU this is a VPU reduction: each grid step streams one (Bt, cb, H, W)
channel slab HBM->VMEM and reduces it to ``cb`` lanes. Batch-dim streaming
(grid minor axis) keeps the VMEM block at (1, cb, H, W) with an accumulator
revisited per batch step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _importance_kernel(g_ref, o_ref, *, bt_steps: int, denom: float):
    b = pl.program_id(1)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (1, cb, H, W) slab -> (cb,) partial sums of |g|
    part = jnp.sum(jnp.abs(g_ref[0]), axis=(1, 2))
    o_ref[...] += part.astype(o_ref.dtype)

    @pl.when(b == bt_steps - 1)
    def _fin():
        o_ref[...] = o_ref[...] * (1.0 / denom)


@functools.partial(jax.jit, static_argnames=("cb", "interpret"))
def channel_importance(g, *, cb: int = 8, interpret: bool = True):
    """(Bt,C,H,W) -> (C,) mean |g| over (Bt, H, W); matches importance_ref."""
    bt, c, h, w = g.shape
    cb = min(cb, c)
    cpad = (c + cb - 1) // cb * cb
    gp = jnp.pad(g, ((0, 0), (0, cpad - c), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_importance_kernel, bt_steps=bt, denom=float(bt * h * w)),
        grid=(cpad // cb, bt),
        in_specs=[pl.BlockSpec((1, cb, h, w), lambda i, b: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((cb,), lambda i, b: (i,)),
        out_shape=jax.ShapeDtypeStruct((cpad,), jnp.float32),
        interpret=interpret,
    )(gp)
    return out[:c].astype(g.dtype)
