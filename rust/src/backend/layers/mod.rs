//! Composable layer-graph model API: the [`Layer`] trait, its concrete
//! building blocks, and the [`Graph`] container — topologically-ordered
//! nodes with residual (skip) connections — that trains any wiring of
//! them through the [`Backend`] trait with ssProp sparsification.
//! [`Sequential`] is the chain-shaped special case, kept as a thin
//! constructor ([`Graph::new`]) over the graph.
//!
//! The paper's central claim is that scheduled sparse BP is a *module*
//! that drops into any architecture; this subsystem is that claim made
//! concrete on the native path — including the residual/BatchNorm family
//! its headline tables measure. A [`Layer`] owns its parameters and
//! computes forward/backward over a borrowed per-node workspace
//! ([`LayerWs`] — the conv plan, pool argmax, dropout mask, BN batch
//! statistics); [`Graph`] owns the node list plus one workspace per node,
//! drives the drop-rate schedule across every conv layer (residual
//! branches and projection shortcuts included), applies SGD updates, and
//! reports [`StepStats`] exactly as the historical hand-rolled
//! `SimpleCnn` did. The data-parallel executor
//! ([`crate::backend::parallel`]) runs the same nodes over per-worker
//! workspaces with *global* cross-shard channel selection and
//! cross-shard BatchNorm statistics.
//!
//! Numerics contract: a chain built by [`crate::backend::simple_cnn`]
//! replays the legacy model **bitwise** — each layer's loops are the
//! exact FP operations of the old fused path in the same order (pinned
//! by `rust/tests/layer_graph_equivalence.rs`).

mod act;
mod conv;
pub(crate) mod graph;
mod linear;
mod norm;
mod pool;

pub use act::{Dropout, ReLU};
pub use conv::Conv2dLayer;
pub use graph::{Graph, GraphBuilder};
pub use linear::{Flatten, Linear};
pub use norm::BatchNorm2d;
pub use pool::{AvgPool2d, GlobalAvgPool, MaxPool2d};

use anyhow::{bail, Result};

use super::plan::Conv2dPlan;
use super::{Backend, Conv2d};
use crate::flops::LayerSet;

/// The chain-shaped layer graph — the historical container name, now a
/// thin constructor over [`Graph`] (see [`Graph::new`]). Every existing
/// call site and checkpoint keeps working unchanged.
pub type Sequential = Graph;

/// The graph-input activation slot ([`GraphBuilder`] wiring anchor).
pub const INPUT_SLOT: usize = 0;

/// Per-example activation geometry flowing between layers: NCHW feature
/// maps ([`Shape::Spatial`]) or flattened feature vectors ([`Shape::Flat`]).
/// The batch dimension is carried separately, so one `Shape` describes a
/// layer at any batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// A (C, H, W) feature map (NCHW with the batch dimension stripped).
    Spatial {
        /// Channels.
        c: usize,
        /// Height.
        h: usize,
        /// Width.
        w: usize,
    },
    /// A flat feature vector (classifier head territory).
    Flat {
        /// Feature count.
        features: usize,
    },
}

impl Shape {
    /// Scalar count per example.
    pub fn volume(&self) -> usize {
        match *self {
            Shape::Spatial { c, h, w } => c * h * w,
            Shape::Flat { features } => features,
        }
    }
}

/// Forward-pass context: train/eval mode plus the deterministic stream
/// coordinates stochastic layers (Dropout) key their masks on. Keying on
/// the *global* example index makes a sharded forward reproduce the serial
/// masks exactly, whatever the thread count.
#[derive(Debug, Clone, Copy)]
pub struct FwdCtx {
    /// Training mode (Dropout masks, BatchNorm batch statistics; eval is
    /// deterministic — identity dropout, running-stat normalization).
    pub train: bool,
    /// Monotone step counter (one dropout mask stream per step).
    pub step: u64,
    /// Global index of this (sub-)batch's first example.
    pub example_offset: usize,
}

/// How a conv layer's backward chooses its ssProp channels.
#[derive(Debug, Clone, Copy)]
pub enum Selection<'a> {
    /// Select locally from this (sub-)batch's gradient at the given drop
    /// rate — the serial path.
    Local(f64),
    /// Back-propagate exactly these output channels (ascending) — the
    /// data-parallel path, where selection is reduced globally across
    /// shards before any shard runs its backward.
    Keep(&'a [usize]),
}

/// One node's reusable per-(worker, batch) scratch. A plain struct rather
/// than a per-layer associated type so the executor can own a uniform
/// `Vec<LayerWs>` per worker; unused fields stay empty and cost nothing.
#[derive(Debug, Default)]
pub struct LayerWs {
    /// Conv layers: the plan (im2col cache + backward scratch).
    pub(crate) plan: Option<Conv2dPlan>,
    /// MaxPool: flat input index of each output's argmax, recorded by the
    /// forward and consumed by the backward scatter.
    pub(crate) argmax: Vec<usize>,
    /// Dropout: the scaled keep mask of the current training forward
    /// (empty in eval mode or at rate 0).
    pub(crate) mask: Vec<f32>,
    /// BatchNorm: normalized activations of the last training forward
    /// (this worker's shard), consumed by the backward.
    pub(crate) xhat: Vec<f32>,
    /// BatchNorm: finalized batch statistics `[mean(C) ‖ var(C)]` of the
    /// last training forward — *global* across shards on the executor
    /// path — consumed by the backward and by [`Layer::commit_stats`].
    pub(crate) stats: Vec<f32>,
    /// Per-channel element count behind `stats` (global batch · H · W).
    pub(crate) stat_count: usize,
}

impl LayerWs {
    /// Capacity fingerprint of the conv plan, if this workspace holds one
    /// (workspace-reuse tests pin these flat across steps).
    pub fn plan_caps(&self) -> Option<[usize; 7]> {
        self.plan.as_ref().map(|p| p.buffer_caps())
    }

    /// im2col builds of the conv plan, if any.
    pub fn plan_cols_builds(&self) -> u64 {
        self.plan.as_ref().map_or(0, |p| p.cols_builds())
    }
}

/// A named view of one parameter tensor (checkpoint export).
#[derive(Debug)]
pub struct ParamView<'a> {
    /// Field name within the layer ("w", "b", BN "rm"/"rv").
    pub field: &'static str,
    /// Flattened values.
    pub data: &'a [f32],
    /// Tensor shape.
    pub shape: Vec<usize>,
}

/// What one layer's backward hands back to its container.
#[derive(Debug, Default)]
pub struct BwdOut {
    /// d loss / d input — empty when the caller passed `need_dx = false`.
    pub dx: Vec<f32>,
    /// Parameter gradients, aligned with [`Layer::params_mut`] order
    /// (empty for stateless layers).
    pub grads: Vec<Vec<f32>>,
    /// Output channels actually back-propagated (conv layers; 0 elsewhere).
    pub kept: usize,
}

/// One node of a layer graph: owns its parameters, computes forward and
/// backward over a borrowed [`LayerWs`], and describes its geometry and
/// FLOPs contribution. Implementations must be `Send + Sync` so the
/// data-parallel executor can share the (read-only) layer list across
/// worker threads — all mutable per-step state lives in the workspace
/// (persistent state like BN running statistics folds in once per step
/// via [`Layer::commit_stats`]).
pub trait Layer: std::fmt::Debug + Send + Sync {
    /// Short human-readable description ("conv3x3/s2 1->8").
    fn describe(&self) -> String;

    /// Output shape for `input`, or an error when the geometry mismatches
    /// what the layer was built for.
    fn out_shape(&self, input: &Shape) -> Result<Shape>;

    /// Key the workspace to batch size `bt` (conv plans re-key in place,
    /// preserving capacity). Default: stateless layers need nothing.
    fn ensure_ws(&self, _ws: &mut LayerWs, _bt: usize) {}

    /// Forward over a batch of `bt` examples; may cache into `ws` whatever
    /// the matching backward needs (im2col columns, argmax, masks, BN
    /// batch statistics).
    fn forward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        ctx: &FwdCtx,
    ) -> Vec<f32>;

    /// Backward: `x` is the same input the last forward saw, `g` is
    /// d loss / d output. `need_dx = false` skips the input-gradient
    /// computation (a node fed by the graph input never consumes it).
    fn backward(
        &self,
        be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut;

    /// Parameter tensors for checkpointing. Update-order parameters come
    /// first, aligned with [`Layer::params_mut`]; non-learned state
    /// (BN running statistics) follows.
    fn params(&self) -> Vec<ParamView<'_>> {
        Vec::new()
    }

    /// Mutable parameter arrays, aligned with [`BwdOut::grads`].
    fn params_mut(&mut self) -> Vec<&mut Vec<f32>> {
        Vec::new()
    }

    /// Restore one parameter field saved via [`Layer::params`].
    fn load_param(&mut self, field: &str, _vals: Vec<f32>) -> Result<()> {
        bail!("layer {:?} has no parameter field {field:?}", self.describe())
    }

    /// Conv layers: the batch-1 geometry (the ssProp selection unit).
    /// `None` for every layer that does not participate in channel
    /// selection.
    fn conv_geom(&self) -> Option<Conv2d> {
        None
    }

    /// Contribute this layer to the Eq. 6–9 FLOPs inventory.
    fn account_flops(&self, _set: &mut LayerSet) {}

    /// BatchNorm folding hook: per-channel `(scale, shift)` such that this
    /// layer's *eval* forward is exactly `y = scale·x + shift` — `Some` only
    /// for [`BatchNorm2d`], whose running statistics and γ/β the fold pass
    /// ([`crate::backend::fold`]) multiplies into the preceding conv.
    /// Default: `None` (the layer cannot be folded away).
    fn bn_fold_factors(&self) -> Option<(Vec<f32>, Vec<f32>)> {
        None
    }

    /// `true` when the training forward normalizes over the *batch*
    /// dimension (BatchNorm): the data-parallel executor must reduce this
    /// layer's statistics partials across shards — at a barrier, in fixed
    /// shard order — before any shard normalizes or back-propagates.
    fn needs_batch_stats(&self) -> bool {
        false
    }

    /// Forward-pass statistics partials over this (sub-)batch — for
    /// BatchNorm, per-channel `[Σx ‖ Σx²]` — summed across shards by the
    /// executor and handed to [`Layer::forward_with_stats`]. Layers
    /// without batch statistics return an empty vector.
    fn fwd_stat_partials(&self, _x: &[f32], _bt: usize) -> Vec<f32> {
        Vec::new()
    }

    /// Training forward with externally reduced statistics partials
    /// (`examples` = the *global* example count behind them). The serial
    /// path calls this with its own partials, so one shard reproduces the
    /// serial arithmetic bitwise. Only meaningful when
    /// [`Layer::needs_batch_stats`] is `true`.
    fn forward_with_stats(
        &self,
        _be: &dyn Backend,
        _x: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
        _partials: &[f32],
        _examples: usize,
    ) -> Vec<f32> {
        unreachable!("layer {:?} has no batch-statistics forward", self.describe())
    }

    /// Backward-pass statistics partials over this (sub-)batch — for
    /// BatchNorm, per-channel `[Σg ‖ Σ(g·x̂)]` — summed across shards and
    /// handed to [`Layer::backward_with_stats`]. Empty for layers whose
    /// backward is shard-local.
    fn bwd_stat_partials(&self, _g: &[f32], _bt: usize, _ws: &LayerWs) -> Vec<f32> {
        Vec::new()
    }

    /// Backward with externally reduced gradient-statistics partials in
    /// `partials` (the exact through-the-batch-statistics gradient needs
    /// global sums) plus this shard's own `local_partials` — the caller
    /// computed those via [`Layer::bwd_stat_partials`] to publish for
    /// reduction, and they double as the returned parameter-gradient
    /// partials, which the executor's fixed-order tree reduction sums to
    /// the global gradient. The serial path passes the same slice twice.
    /// Only meaningful when [`Layer::needs_batch_stats`] is `true`.
    fn backward_with_stats(
        &self,
        _be: &dyn Backend,
        _x: &[f32],
        _g: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _partials: &[f32],
        _local_partials: &[f32],
        _need_dx: bool,
    ) -> BwdOut {
        unreachable!("layer {:?} has no batch-statistics backward", self.describe())
    }

    /// Fold the batch statistics the last *training* forward left in `ws`
    /// into persistent layer state (BatchNorm running statistics). Called
    /// exactly once per training step by the container — after the
    /// backward — and by the executor with worker 0's workspace (whose
    /// statistics are the reduced global ones). Default: no-op.
    fn commit_stats(&mut self, _ws: &LayerWs) {}
}

/// Per-step statistics returned by [`Graph::train_step`].
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Mean softmax cross-entropy over the batch.
    pub loss: f64,
    /// Fraction of the batch classified correctly.
    pub acc: f64,
    /// Output channels actually back-propagated, summed over conv layers.
    pub kept_channels: usize,
    /// Total output channels over conv layers (kept == total when dense).
    pub total_channels: usize,
}

/// Softmax cross-entropy core over integer labels for a (sub-)batch:
/// returns (sum of per-example losses, correct count, d loss / d logits)
/// with `1 / grad_denom` folded into the gradient. The serial step passes
/// `grad_denom = bt`; the data-parallel executor passes the *full* batch
/// size from every shard, so per-shard gradients are already in full-batch
/// units and reduce by plain summation.
pub(crate) fn softmax_ce_core(
    logits: &[f32],
    y: &[i32],
    classes: usize,
    grad_denom: usize,
) -> (f64, usize, Vec<f32>) {
    let bt = y.len();
    // The loss/argmax forward is the per-example routine; summing its
    // losses in example order reproduces the historical accumulation
    // bit-for-bit, and the softmax terms below recompute deterministically.
    let (losses, correct) = softmax_ce_examples(logits, y, classes);
    let mut loss = 0f64;
    for &l in &losses {
        loss += l;
    }
    let mut dlogits = vec![0f32; bt * classes];
    for b in 0..bt {
        let row = &logits[b * classes..][..classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let label = y[b] as usize;
        let drow = &mut dlogits[b * classes..][..classes];
        for (c, &v) in row.iter().enumerate() {
            let p = (v - max).exp() / denom;
            drow[c] = (p - if c == label { 1.0 } else { 0.0 }) / grad_denom as f32;
        }
    }
    (loss, correct, dlogits)
}

/// Per-example softmax cross-entropy (no gradient): returns each example's
/// loss plus the correct count. Shard workers hand these back so the
/// reducer can sum losses in *global example order* — which makes sharded
/// evaluation bit-identical to serial evaluation at any thread count.
pub(crate) fn softmax_ce_examples(logits: &[f32], y: &[i32], classes: usize) -> (Vec<f64>, usize) {
    let bt = y.len();
    let mut losses = Vec::with_capacity(bt);
    let mut correct = 0usize;
    for b in 0..bt {
        let row = &logits[b * classes..][..classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let label = y[b] as usize;
        losses.push((denom.ln() - (row[label] - max)) as f64);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if argmax == label {
            correct += 1;
        }
    }
    (losses, correct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_ce_examples_matches_core() {
        let logits = vec![0.3, -0.2, 0.9, 0.1, 0.0, -0.5];
        let y = vec![2, 0];
        let (sum, correct, _) = softmax_ce_core(&logits, &y, 3, 2);
        let (each, correct2) = softmax_ce_examples(&logits, &y, 3);
        assert_eq!(correct, correct2);
        let mut acc = 0f64;
        for &l in &each {
            acc += l;
        }
        assert_eq!(acc, sum, "per-example losses must sum to the core's loss");
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let (losses, _) = softmax_ce_examples(&[0.0, 0.0, 0.0, 0.0], &[1, 0], 2);
        for l in losses {
            assert!((l - (2f64).ln()).abs() < 1e-6);
        }
        let (_, _, d) = softmax_ce_core(&[0.0, 0.0, 0.0, 0.0], &[1, 0], 2, 2);
        assert!((d[0] + d[1]).abs() < 1e-6, "gradient rows sum to zero");
        assert!((d[2] + d[3]).abs() < 1e-6);
    }
}
