//! Native SimpleCNN: the paper's Fig. 4 workhorse, trained entirely through
//! the [`Backend`] trait — conv stack (3×3, first layer stride 2) + ReLU,
//! global average pool, linear classifier, softmax cross-entropy, SGD.
//!
//! The model owns one [`Conv2dPlan`] per conv layer, so `train_step` runs
//! the planned path: the forward caches each layer's im2col columns in its
//! plan and the ssProp backward ([`Backend::conv2d_bwd_planned`]) consumes
//! them — exactly one patch gather per layer per step, zero steady-state
//! allocation in the plan buffers. A drop-rate schedule sparsifies
//! training exactly as the AOT/PJRT path does; FLOPs accounting reuses the
//! same Eq. 6/9 [`LayerSet`] machinery.

use anyhow::{bail, Result};

use super::plan::Conv2dPlan;
use super::{Backend, Conv2d};
use crate::flops::{ConvLayer, LayerSet};
use crate::tensorstore::Tensor;
use crate::util::rng::Pcg;

/// Geometry/init knobs for a native SimpleCNN.
#[derive(Debug, Clone, Copy)]
pub struct SimpleCnnCfg {
    /// Input channels (1 for grayscale datasets, 3 for RGB).
    pub in_ch: usize,
    /// Input image side length (images are square).
    pub img: usize,
    /// Number of classifier outputs.
    pub classes: usize,
    /// Number of 3×3 conv layers (≥ 1); the first is stride 2.
    pub depth: usize,
    /// Channels per conv layer.
    pub width: usize,
    /// Parameter-init seed (two models built from equal cfgs are equal).
    pub seed: u64,
}

/// One conv layer's parameters.
#[derive(Debug, Clone)]
pub struct ConvBlock {
    /// Weights, (width, cin, 3, 3) flattened OIHW.
    pub w: Vec<f32>,
    /// Bias, (width,).
    pub b: Vec<f32>,
    /// Input channels of this layer.
    pub cin: usize,
    /// Stride (2 on the stem layer, 1 elsewhere).
    pub stride: usize,
}

/// Per-step statistics returned by [`SimpleCnn::train_step`].
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    /// Mean softmax cross-entropy over the batch.
    pub loss: f64,
    /// Fraction of the batch classified correctly.
    pub acc: f64,
    /// Output channels actually back-propagated, summed over conv layers.
    pub kept_channels: usize,
    /// Total output channels over conv layers (kept == total when dense).
    pub total_channels: usize,
}

/// The paper's Fig. 4 workhorse model (see module docs), trained entirely
/// through the [`Backend`] trait.
#[derive(Debug, Clone)]
pub struct SimpleCnn {
    /// Geometry/init knobs the model was built from.
    pub cfg: SimpleCnnCfg,
    /// Conv stack parameters, index 0 = the stride-2 stem.
    pub convs: Vec<ConvBlock>,
    /// Classifier weights, (width, classes) row-major.
    pub fc_w: Vec<f32>,
    /// Classifier bias, (classes,).
    pub fc_b: Vec<f32>,
    /// Per-layer conv plans (im2col cache + backward scratch), re-keyed by
    /// [`SimpleCnn::ensure_plans`] when the batch size changes.
    plans: Vec<Conv2dPlan>,
}

impl SimpleCnn {
    /// Build and He-initialize a model from `cfg` (deterministic per seed).
    pub fn new(cfg: SimpleCnnCfg) -> SimpleCnn {
        assert!(cfg.depth >= 1 && cfg.width >= 1 && cfg.classes >= 1);
        let mut rng = Pcg::new(cfg.seed ^ 0xC44, 29);
        let mut convs = Vec::with_capacity(cfg.depth);
        for l in 0..cfg.depth {
            let cin = if l == 0 { cfg.in_ch } else { cfg.width };
            let fan_in = (cin * 9) as f32;
            let scale = (2.0 / fan_in).sqrt();
            convs.push(ConvBlock {
                w: (0..cfg.width * cin * 9).map(|_| rng.normal() * scale).collect(),
                b: vec![0f32; cfg.width],
                cin,
                stride: if l == 0 { 2 } else { 1 },
            });
        }
        let fc_scale = (2.0 / cfg.width as f32).sqrt();
        SimpleCnn {
            cfg,
            convs,
            fc_w: (0..cfg.width * cfg.classes).map(|_| rng.normal() * fc_scale).collect(),
            fc_b: vec![0f32; cfg.classes],
            plans: Vec::new(),
        }
    }

    /// Key the per-layer plans to batch size `bt`, preserving every
    /// buffer's capacity. Called by `train_step`; also useful to prewarm
    /// before a timed loop.
    pub fn ensure_plans(&mut self, bt: usize) {
        for l in 0..self.cfg.depth {
            let cfg = self.conv_cfg(l, bt);
            if l < self.plans.len() {
                self.plans[l].ensure(cfg);
            } else {
                self.plans.push(Conv2dPlan::new(cfg));
            }
        }
    }

    /// Read-only view of the per-layer plans (workspace-reuse tests).
    pub fn plans(&self) -> &[Conv2dPlan] {
        &self.plans
    }

    /// Total im2col materializations across layers since construction —
    /// advances by exactly `depth` per `train_step` on the fused path.
    pub fn plan_cols_builds(&self) -> u64 {
        self.plans.iter().map(|p| p.cols_builds()).sum()
    }

    /// Spatial size of layer `l`'s input feature map.
    fn in_size(&self, l: usize) -> usize {
        if l == 0 {
            self.cfg.img
        } else {
            super::im2col::out_size(self.cfg.img, 3, 2, 1)
        }
    }

    /// Conv geometry for layer `l` at batch size `bt`.
    pub fn conv_cfg(&self, l: usize, bt: usize) -> Conv2d {
        let s = self.in_size(l);
        Conv2d {
            bt,
            cin: self.convs[l].cin,
            h: s,
            w: s,
            cout: self.cfg.width,
            k: 3,
            stride: self.convs[l].stride,
            padding: 1,
        }
    }

    /// Conv inventory for Eq. 6/9 FLOPs accounting (no BN in this model).
    pub fn layer_set(&self) -> LayerSet {
        let mut set = LayerSet::default();
        for l in 0..self.cfg.depth {
            let c = self.conv_cfg(l, 1);
            set.convs.push(ConvLayer {
                cin: c.cin,
                cout: c.cout,
                k: c.k,
                hout: c.hout(),
                wout: c.wout(),
                counted_bn: false,
            });
        }
        set
    }

    /// Forward pass keeping every intermediate needed for backward:
    /// `acts[l]` is layer l's input (acts[0] = x), `zs[l]` its pre-ReLU
    /// output; returns (acts, zs, pooled, logits). Runs through the
    /// planned path, leaving each layer's im2col columns cached in its
    /// plan for the backward. Crate-visible so the data-parallel executor
    /// can run the identical forward per shard on per-worker plans.
    #[allow(clippy::type_complexity)]
    pub(crate) fn forward(
        &self,
        backend: &dyn Backend,
        x: &[f32],
        bt: usize,
        plans: &mut [Conv2dPlan],
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<f32>, Vec<f32>) {
        let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
        let mut zs: Vec<Vec<f32>> = Vec::with_capacity(self.cfg.depth);
        for l in 0..self.cfg.depth {
            let cb = &self.convs[l];
            let z = backend.conv2d_fwd_planned(&mut plans[l], &acts[l], &cb.w, Some(&cb.b));
            let a: Vec<f32> = z.iter().map(|&v| v.max(0.0)).collect();
            zs.push(z);
            acts.push(a);
        }
        // global average pool over the last feature map -> (bt, width)
        let last = self.conv_cfg(self.cfg.depth - 1, bt);
        let hw = last.hout() * last.wout();
        let width = self.cfg.width;
        let mut pooled = vec![0f32; bt * width];
        let top = &acts[self.cfg.depth];
        for b in 0..bt {
            for f in 0..width {
                let plane = &top[(b * width + f) * hw..][..hw];
                pooled[b * width + f] = plane.iter().sum::<f32>() / hw as f32;
            }
        }
        // logits = pooled . fc_w + fc_b
        let classes = self.cfg.classes;
        let mut logits = backend.gemm(bt, width, classes, &pooled, &self.fc_w);
        for b in 0..bt {
            for (c, &bias) in self.fc_b.iter().enumerate() {
                logits[b * classes + c] += bias;
            }
        }
        (acts, zs, pooled, logits)
    }

    /// Classifier-head backward for a (sub-)batch: given the pooled
    /// features and `dlogits`, returns (d fc_w, d fc_b, d pooled). Pure
    /// gradient computation (no update), so the serial step and the
    /// data-parallel executor's shard workers share it verbatim — the
    /// executor tree-reduces the returned pieces across shards.
    #[allow(clippy::type_complexity)]
    pub(crate) fn head_backward(
        &self,
        pooled: &[f32],
        dlogits: &[f32],
        bt: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (width, classes) = (self.cfg.width, self.cfg.classes);
        let mut dpooled = vec![0f32; bt * width];
        for b in 0..bt {
            let drow = &dlogits[b * classes..][..classes];
            for f in 0..width {
                let wrow = &self.fc_w[f * classes..][..classes];
                let mut acc_dp = 0f32;
                for (dv, wv) in drow.iter().zip(wrow) {
                    acc_dp += dv * wv;
                }
                dpooled[b * width + f] = acc_dp;
            }
        }
        let mut dfc_w = vec![0f32; width * classes];
        let mut dfc_b = vec![0f32; classes];
        for b in 0..bt {
            let drow = &dlogits[b * classes..][..classes];
            let prow = &pooled[b * width..][..width];
            for (f, &pv) in prow.iter().enumerate() {
                let dst = &mut dfc_w[f * classes..][..classes];
                for (dw, &dv) in dst.iter_mut().zip(drow) {
                    *dw += pv * dv;
                }
            }
            for (db, &dv) in dfc_b.iter_mut().zip(drow) {
                *db += dv;
            }
        }
        (dfc_w, dfc_b, dpooled)
    }

    /// Global-average-pool backward through the top ReLU: spread `dpooled`
    /// uniformly over each feature plane, zeroing pixels whose pre-ReLU
    /// activation `ztop` was non-positive. Shared by the serial step and
    /// the shard workers (each passes its own sub-batch slices).
    pub(crate) fn pool_backward(&self, dpooled: &[f32], ztop: &[f32], bt: usize) -> Vec<f32> {
        let width = self.cfg.width;
        let last = self.conv_cfg(self.cfg.depth - 1, bt);
        let hw = last.hout() * last.wout();
        let inv_hw = 1.0 / hw as f32;
        let mut g = vec![0f32; bt * width * hw];
        for b in 0..bt {
            for f in 0..width {
                let gv = dpooled[b * width + f] * inv_hw;
                let base = (b * width + f) * hw;
                for pix in 0..hw {
                    if ztop[base + pix] > 0.0 {
                        g[base + pix] = gv;
                    }
                }
            }
        }
        g
    }

    /// One SGD training step at `drop_rate`; returns loss/acc/kept-channel
    /// stats. `x` is (bt, in_ch, img, img) flattened, `y` integer labels.
    pub fn train_step(
        &mut self,
        backend: &dyn Backend,
        x: &[f32],
        y: &[i32],
        drop_rate: f64,
        lr: f32,
    ) -> Result<StepStats> {
        let bt = y.len();
        if bt == 0 || x.len() != bt * self.cfg.in_ch * self.cfg.img * self.cfg.img {
            bail!("bad batch geometry: {} inputs for {bt} labels", x.len());
        }
        // Planned path: take the plans out so the forward can borrow them
        // alongside `self`; the forward caches each layer's cols in its
        // plan and the backward below consumes them — one im2col per
        // layer per step.
        self.ensure_plans(bt);
        let mut plans = std::mem::take(&mut self.plans);
        let (acts, zs, pooled, logits) = self.forward(backend, x, bt, &mut plans);
        self.plans = plans;
        let (loss_sum, correct, dlogits) = softmax_ce_core(&logits, y, self.cfg.classes, bt);
        let loss = loss_sum / bt as f64;
        let acc = correct as f64 / bt as f64;
        if !loss.is_finite() {
            bail!("non-finite loss at drop rate {drop_rate}");
        }

        // FC backward + update, then pool backward -> gradient on the top
        // feature map through its ReLU
        let (dfc_w, dfc_b, dpooled) = self.head_backward(&pooled, &dlogits, bt);
        let mut g = self.pool_backward(&dpooled, &zs[self.cfg.depth - 1], bt);
        for (wv, &dv) in self.fc_w.iter_mut().zip(&dfc_w) {
            *wv -= lr * dv;
        }
        for (bv, &dv) in self.fc_b.iter_mut().zip(&dfc_b) {
            *bv -= lr * dv;
        }

        // conv stack backward (ssProp-selected) + SGD updates, consuming
        // the im2col columns the forward cached in each layer's plan — no
        // patch re-gather (this was the ROADMAP "cols built twice" item).
        let mut kept = 0usize;
        for l in (0..self.cfg.depth).rev() {
            // layer 0 never consumes dx — let the backend skip that GEMM
            let grads = backend.conv2d_bwd_planned(
                &mut self.plans[l],
                &acts[l],
                &self.convs[l].w,
                &g,
                drop_rate,
                l > 0,
            );
            kept += grads.keep_idx.len();
            for (wv, &dv) in self.convs[l].w.iter_mut().zip(&grads.dw) {
                *wv -= lr * dv;
            }
            for (bv, &dv) in self.convs[l].b.iter_mut().zip(&grads.db) {
                *bv -= lr * dv;
            }
            if l > 0 {
                let zprev = &zs[l - 1];
                g = grads.dx;
                for (gv, &zv) in g.iter_mut().zip(zprev) {
                    if zv <= 0.0 {
                        *gv = 0.0;
                    }
                }
            }
        }

        Ok(StepStats {
            loss,
            acc,
            kept_channels: kept,
            total_channels: self.cfg.depth * self.cfg.width,
        })
    }

    /// Forward-only loss/accuracy on a batch (throwaway plans: eval has no
    /// backward to reuse the columns, and `&self` keeps it shareable).
    pub fn eval_batch(&self, backend: &dyn Backend, x: &[f32], y: &[i32]) -> (f64, f64) {
        let bt = y.len();
        let mut plans: Vec<Conv2dPlan> =
            (0..self.cfg.depth).map(|l| Conv2dPlan::new(self.conv_cfg(l, bt))).collect();
        let (_, _, _, logits) = self.forward(backend, x, bt, &mut plans);
        let (loss, acc, _) = softmax_ce(&logits, y, self.cfg.classes);
        (loss, acc)
    }

    /// Parameters as named tensors (checkpoint format shared with the AOT
    /// path's `*.init.tstore`).
    pub fn state_tensors(&self) -> Vec<(String, Tensor)> {
        let mut out = Vec::new();
        for (l, cb) in self.convs.iter().enumerate() {
            let shape = vec![self.cfg.width, cb.cin, 3, 3];
            out.push((format!("param['conv{l}.w']"), Tensor::from_f32(shape, &cb.w)));
            let bias = Tensor::from_f32(vec![self.cfg.width], &cb.b);
            out.push((format!("param['conv{l}.b']"), bias));
        }
        out.push((
            "param['fc.w']".to_string(),
            Tensor::from_f32(vec![self.cfg.width, self.cfg.classes], &self.fc_w),
        ));
        out.push((
            "param['fc.b']".to_string(),
            Tensor::from_f32(vec![self.cfg.classes], &self.fc_b),
        ));
        out
    }

    /// Restore parameters saved by [`SimpleCnn::state_tensors`].
    pub fn load_state_tensors(&mut self, tensors: &[(String, Tensor)]) -> Result<()> {
        for (name, t) in tensors {
            let vals = t.to_f32();
            let dst: &mut Vec<f32> = if let Some(rest) = name.strip_prefix("param['conv") {
                let (idx, field) = rest
                    .split_once('.')
                    .map(|(i, f)| (i, f.trim_end_matches("']")))
                    .unwrap_or(("", ""));
                let l: usize = idx.parse().map_err(|_| anyhow::anyhow!("bad layer in {name:?}"))?;
                if l >= self.convs.len() {
                    bail!("checkpoint layer {l} out of range");
                }
                match field {
                    "w" => &mut self.convs[l].w,
                    "b" => &mut self.convs[l].b,
                    other => bail!("unknown conv field {other:?} in {name:?}"),
                }
            } else {
                match name.as_str() {
                    "param['fc.w']" => &mut self.fc_w,
                    "param['fc.b']" => &mut self.fc_b,
                    other => bail!("unknown state leaf {other:?}"),
                }
            };
            if dst.len() != vals.len() {
                bail!("shape mismatch for {name:?}: {} vs {}", vals.len(), dst.len());
            }
            *dst = vals;
        }
        Ok(())
    }
}

/// Softmax cross-entropy core over integer labels for a (sub-)batch:
/// returns (sum of per-example losses, correct count, d loss / d logits)
/// with `1 / grad_denom` folded into the gradient. The serial step passes
/// `grad_denom = bt`; the data-parallel executor passes the *full* batch
/// size from every shard, so per-shard gradients are already in full-batch
/// units and reduce by plain summation.
pub(crate) fn softmax_ce_core(
    logits: &[f32],
    y: &[i32],
    classes: usize,
    grad_denom: usize,
) -> (f64, usize, Vec<f32>) {
    let bt = y.len();
    let mut dlogits = vec![0f32; bt * classes];
    let (mut loss, mut correct) = (0f64, 0usize);
    for b in 0..bt {
        let row = &logits[b * classes..][..classes];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut denom = 0f32;
        for &v in row {
            denom += (v - max).exp();
        }
        let label = y[b] as usize;
        loss += (denom.ln() - (row[label] - max)) as f64;
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if argmax == label {
            correct += 1;
        }
        let drow = &mut dlogits[b * classes..][..classes];
        for (c, &v) in row.iter().enumerate() {
            let p = (v - max).exp() / denom;
            drow[c] = (p - if c == label { 1.0 } else { 0.0 }) / grad_denom as f32;
        }
    }
    (loss, correct, dlogits)
}

/// Softmax cross-entropy over integer labels: returns (mean loss, accuracy,
/// d loss / d logits) with the 1/Bt factor folded into the gradient.
fn softmax_ce(logits: &[f32], y: &[i32], classes: usize) -> (f64, f64, Vec<f32>) {
    let bt = y.len();
    let (loss_sum, correct, dlogits) = softmax_ce_core(logits, y, classes, bt);
    (loss_sum / bt as f64, correct as f64 / bt as f64, dlogits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn tiny() -> SimpleCnn {
        SimpleCnn::new(SimpleCnnCfg { in_ch: 1, img: 8, classes: 3, depth: 2, width: 4, seed: 7 })
    }

    fn batch(model: &SimpleCnn, bt: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg::new(seed, 1);
        let n = model.cfg.in_ch * model.cfg.img * model.cfg.img;
        let x = (0..bt * n).map(|_| rng.normal()).collect();
        let y = (0..bt).map(|i| (i % model.cfg.classes) as i32).collect();
        (x, y)
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let (loss, acc, d) = softmax_ce(&[0.0, 0.0, 0.0, 0.0], &[1, 0], 2);
        assert!((loss - (2f64).ln()).abs() < 1e-6);
        assert!((0.0..=1.0).contains(&acc));
        // gradient rows sum to zero (softmax minus one-hot)
        assert!((d[0] + d[1]).abs() < 1e-6);
        assert!((d[2] + d[3]).abs() < 1e-6);
    }

    #[test]
    fn train_step_reduces_loss_on_fixed_batch() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(&m, 6, 3);
        let first = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        for _ in 0..20 {
            m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        }
        let last = m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert!(last.loss < first.loss, "{} -> {}", first.loss, last.loss);
        assert_eq!(first.kept_channels, first.total_channels);
    }

    #[test]
    fn sparse_step_keeps_fewer_channels_and_diverges_from_dense() {
        let be = NativeBackend::new();
        let mut dense = tiny();
        let mut sparse = tiny();
        let (x, y) = batch(&dense, 4, 9);
        dense.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let stats = sparse.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        // width 4 at D=0.8: keep round(0.8) = 1 channel per layer
        assert_eq!(stats.kept_channels, 2);
        assert_eq!(stats.total_channels, 8);
        assert_ne!(dense.convs[0].w, sparse.convs[0].w);
    }

    #[test]
    fn train_step_builds_cols_once_per_layer() {
        let be = NativeBackend::new();
        let mut m = tiny();
        let (x, y) = batch(&m, 4, 13);
        assert_eq!(m.plan_cols_builds(), 0);
        m.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        assert_eq!(m.plan_cols_builds(), m.cfg.depth as u64, "fwd cols reused by bwd");
        m.train_step(&be, &x, &y, 0.8, 0.05).unwrap();
        assert_eq!(m.plan_cols_builds(), 2 * m.cfg.depth as u64);
    }

    #[test]
    fn state_tensor_roundtrip() {
        let mut a = tiny();
        let be = NativeBackend::new();
        let (x, y) = batch(&a, 4, 5);
        a.train_step(&be, &x, &y, 0.0, 0.05).unwrap();
        let saved = a.state_tensors();
        assert_eq!(saved.len(), 2 * 2 + 2);

        let mut b = tiny();
        assert_ne!(a.convs[0].w, b.convs[0].w);
        b.load_state_tensors(&saved).unwrap();
        assert_eq!(a.convs[0].w, b.convs[0].w);
        assert_eq!(a.fc_w, b.fc_w);
        let (la, _) = a.eval_batch(&be, &x, &y);
        let (lb, _) = b.eval_batch(&be, &x, &y);
        assert_eq!(la, lb);
    }

    #[test]
    fn load_rejects_bad_shapes() {
        let mut m = tiny();
        let bad = vec![("param['fc.b']".to_string(), Tensor::from_f32(vec![2], &[0.0, 1.0]))];
        assert!(m.load_state_tensors(&bad).is_err());
        let unknown = vec![("param['nope']".to_string(), Tensor::from_f32(vec![1], &[0.0]))];
        assert!(m.load_state_tensors(&unknown).is_err());
    }
}
