//! Activation layers: ReLU and (inverted) Dropout — the paper's
//! compatibility claim is that ssProp composes with Dropout, so the layer
//! graph carries a real Dropout whose masks are deterministic per
//! (seed, step, global example), making sharded training reproduce the
//! serial masks exactly.

use anyhow::Result;

use super::{BwdOut, FwdCtx, Layer, LayerWs, Selection, Shape};
use crate::backend::Backend;
use crate::flops::LayerSet;
use crate::util::rng::Pcg;

/// Elementwise `max(0, x)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReLU;

impl Layer for ReLU {
    fn describe(&self) -> String {
        "relu".to_string()
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        Ok(*input)
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _ctx: &FwdCtx,
    ) -> Vec<f32> {
        x.iter().map(|&v| v.max(0.0)).collect()
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        g: &[f32],
        _bt: usize,
        _ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        let dx = g.iter().zip(x).map(|(&gv, &xv)| if xv > 0.0 { gv } else { 0.0 }).collect();
        BwdOut { dx, ..BwdOut::default() }
    }
}

/// Inverted dropout: in training, each element is zeroed with probability
/// `rate` and survivors are scaled by `1/(1-rate)`; in eval it is the
/// identity. The mask for a given (step, global example) is a pure
/// function of the layer seed, so any batch sharding reproduces it.
#[derive(Debug, Clone)]
pub struct Dropout {
    /// Drop probability in [0, 1).
    rate: f64,
    /// Per-example activation shape (identity geometry; kept for the
    /// Eq. 8 FLOPs ledger).
    shape: Shape,
    /// Mask stream seed (distinct per dropout layer in a graph).
    seed: u64,
}

impl Dropout {
    /// A dropout layer at `rate` over activations of `shape`, drawing its
    /// masks from `seed`'s stream.
    pub fn new(rate: f64, shape: Shape, seed: u64) -> Dropout {
        assert!((0.0..1.0).contains(&rate), "dropout rate must be in [0, 1), got {rate}");
        Dropout { rate, shape, seed }
    }

    /// The configured drop probability.
    pub fn rate(&self) -> f64 {
        self.rate
    }
}

impl Layer for Dropout {
    fn describe(&self) -> String {
        format!("dropout p{:.2}", self.rate)
    }

    fn out_shape(&self, input: &Shape) -> Result<Shape> {
        if *input != self.shape {
            anyhow::bail!("dropout built for {:?}, got {input:?}", self.shape);
        }
        Ok(*input)
    }

    fn forward(
        &self,
        _be: &dyn Backend,
        x: &[f32],
        bt: usize,
        ws: &mut LayerWs,
        ctx: &FwdCtx,
    ) -> Vec<f32> {
        if !ctx.train || self.rate == 0.0 {
            ws.mask.clear();
            return x.to_vec();
        }
        let n = self.shape.volume();
        let scale = (1.0 / (1.0 - self.rate)) as f32;
        let p = self.rate as f32;
        ws.mask.clear();
        ws.mask.resize(bt * n, 0.0);
        for b in 0..bt {
            // One stream per (step, global example): sharded forwards
            // reproduce the serial masks regardless of shard boundaries.
            let example = (ctx.example_offset + b) as u64;
            let stream_seed = self.seed ^ ctx.step.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut rng = Pcg::new(stream_seed, example);
            let row = &mut ws.mask[b * n..][..n];
            for m in row.iter_mut() {
                *m = if rng.uniform() < p { 0.0 } else { scale };
            }
        }
        x.iter().zip(&ws.mask).map(|(&v, &m)| v * m).collect()
    }

    fn backward(
        &self,
        _be: &dyn Backend,
        _x: &[f32],
        g: &[f32],
        _bt: usize,
        ws: &mut LayerWs,
        _sel: Selection<'_>,
        need_dx: bool,
    ) -> BwdOut {
        if !need_dx {
            return BwdOut::default();
        }
        let dx = if ws.mask.is_empty() {
            g.to_vec()
        } else {
            g.iter().zip(&ws.mask).map(|(&gv, &m)| gv * m).collect()
        };
        BwdOut { dx, ..BwdOut::default() }
    }

    fn account_flops(&self, set: &mut LayerSet) {
        let dims = match self.shape {
            Shape::Spatial { c, h, w } => (c, h, w),
            Shape::Flat { features } => (features, 1, 1),
        };
        set.dropouts.push(dims);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;

    fn ctx(train: bool, step: u64, offset: usize) -> FwdCtx {
        FwdCtx { train, step, example_offset: offset }
    }

    #[test]
    fn relu_forward_backward() {
        let be = NativeBackend::new();
        let r = ReLU;
        let mut ws = LayerWs::default();
        let x = vec![-1.0, 0.0, 2.0, -0.5];
        let y = r.forward(&be, &x, 2, &mut ws, &ctx(true, 0, 0));
        assert_eq!(y, vec![0.0, 0.0, 2.0, 0.0]);
        let g = vec![1.0, 1.0, 1.0, 1.0];
        let out = r.backward(&be, &x, &g, 2, &mut ws, Selection::Local(0.0), true);
        assert_eq!(out.dx, vec![0.0, 0.0, 1.0, 0.0]);
        assert!(out.grads.is_empty());
        let skipped = r.backward(&be, &x, &g, 2, &mut ws, Selection::Local(0.0), false);
        assert!(skipped.dx.is_empty());
    }

    #[test]
    fn dropout_eval_is_identity_and_train_masks() {
        let be = NativeBackend::new();
        let shape = Shape::Flat { features: 64 };
        let d = Dropout::new(0.5, shape, 7);
        let x: Vec<f32> = (0..128).map(|i| i as f32 * 0.1 + 1.0).collect();
        let mut ws = LayerWs::default();
        let ye = d.forward(&be, &x, 2, &mut ws, &ctx(false, 0, 0));
        assert_eq!(ye, x, "eval mode must be the identity");
        assert!(ws.mask.is_empty());

        let yt = d.forward(&be, &x, 2, &mut ws, &ctx(true, 0, 0));
        assert_ne!(yt, x, "training mode must mask");
        let zeros = yt.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 16 && zeros < 112, "about half drop at p=0.5, got {zeros}");
        for (&y, &m) in yt.iter().zip(&ws.mask) {
            assert!(m == 0.0 || (m - 2.0).abs() < 1e-6, "inverted scaling");
            if m == 0.0 {
                assert_eq!(y, 0.0);
            }
        }
    }

    #[test]
    fn dropout_masks_are_shard_invariant() {
        let be = NativeBackend::new();
        let shape = Shape::Flat { features: 16 };
        let d = Dropout::new(0.3, shape, 99);
        let x: Vec<f32> = (0..4 * 16).map(|i| (i % 5) as f32 + 1.0).collect();
        let mut ws = LayerWs::default();
        let full = d.forward(&be, &x, 4, &mut ws, &ctx(true, 3, 0));
        // shard [2, 4) forwarded with the matching global offset
        let mut ws2 = LayerWs::default();
        let tail = d.forward(&be, &x[2 * 16..], 2, &mut ws2, &ctx(true, 3, 2));
        assert_eq!(tail[..], full[2 * 16..], "shard must reproduce the serial mask");
        // a different step draws a different mask
        let mut ws3 = LayerWs::default();
        let other = d.forward(&be, &x, 4, &mut ws3, &ctx(true, 4, 0));
        assert_ne!(other, full);
    }

    #[test]
    fn dropout_backward_applies_the_forward_mask() {
        let be = NativeBackend::new();
        let d = Dropout::new(0.4, Shape::Flat { features: 32 }, 1);
        let x = vec![1.0f32; 32];
        let mut ws = LayerWs::default();
        let y = d.forward(&be, &x, 1, &mut ws, &ctx(true, 0, 0));
        let g = vec![1.0f32; 32];
        let out = d.backward(&be, &x, &g, 1, &mut ws, Selection::Local(0.0), true);
        assert_eq!(out.dx, y, "with unit x and unit g, dx equals the masked forward");
        // eval (empty mask) backward passes the gradient through
        let ye = d.forward(&be, &x, 1, &mut ws, &ctx(false, 0, 0));
        assert_eq!(ye, x);
        let thru = d.backward(&be, &x, &g, 1, &mut ws, Selection::Local(0.0), true);
        assert_eq!(thru.dx, g);
    }

    #[test]
    fn dropout_flops_entry() {
        let mut set = LayerSet::default();
        Dropout::new(0.25, Shape::Spatial { c: 4, h: 3, w: 3 }, 0).account_flops(&mut set);
        Dropout::new(0.25, Shape::Flat { features: 10 }, 0).account_flops(&mut set);
        assert_eq!(set.dropouts, vec![(4, 3, 3), (10, 1, 1)]);
    }

    #[test]
    #[should_panic(expected = "dropout rate")]
    fn dropout_rejects_rate_one() {
        Dropout::new(1.0, Shape::Flat { features: 4 }, 0);
    }
}
